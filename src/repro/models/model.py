"""Model facade: parameter init, loss, prefill/decode steps, cache init.

``init_params`` is jit/eval_shape-friendly, so the dry-run can derive
ShapeDtypeStructs for 314B-parameter configs without allocating a byte.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_lib
from repro.models import transformer
from repro.models.layers import softmax_cross_entropy
from repro.models.transformer import Cache

__all__ = [
    "init_params",
    "abstract_params",
    "init_cache",
    "abstract_cache",
    "train_loss",
    "prefill",
    "decode_step",
]

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def _attn_layer_shapes(cfg):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    shapes = {
        "ln1": (d,),
        "ln2": (d,),
        "wq": (d, h * hd),
        "wk": (d, kv * hd),
        "wv": (d, kv * hd),
        "wo": (h * hd, d),
    }
    if cfg.qkv_bias:
        shapes.update({"bq": (h * hd,), "bk": (kv * hd,), "bv": (kv * hd,)})
    return shapes


def _ffn_shapes(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.num_experts:
        e = cfg.num_experts
        shapes = {
            "router": (d, e),
            "w_gate": (e, d, f),
            "w_up": (e, d, f),
            "w_down": (e, f, d),
        }
        if cfg.dense_residual:
            shapes.update({"wr_gate": (d, f), "wr_up": (d, f), "wr_down": (f, d)})
        return shapes
    return {"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}


def _ssm_layer_shapes(cfg):
    dims = ssm_lib.ssm_dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv)
    return {
        "ln1": (cfg.d_model,),
        "in_proj": (cfg.d_model, dims["d_in_proj"]),
        "conv_w": (dims["conv_k"], dims["conv_dim"]),
        "conv_b": (dims["conv_dim"],),
        "a_log": (dims["nheads"],),
        "d_skip": (dims["nheads"],),
        "dt_bias": (dims["nheads"],),
        "norm_w": (dims["d_inner"],),
        "out_proj": (dims["d_inner"], cfg.d_model),
    }


def param_shapes(cfg) -> dict:
    """Nested dict of shapes; layer stacks carry a leading layer axis."""
    v, d, l = cfg.padded_vocab, cfg.d_model, cfg.num_layers
    out: dict[str, Any] = {"embed": (v, d), "final_norm": (d,)}
    if not cfg.tie_embeddings:
        out["lm_head"] = (v, d)

    if cfg.family == "ssm":
        out["layers"] = {k: (l, *s) for k, s in _ssm_layer_shapes(cfg).items()}
    elif cfg.family == "hybrid":
        n_seg = l // cfg.attn_every
        out["layers"] = {
            k: (n_seg, cfg.attn_every, *s) for k, s in _ssm_layer_shapes(cfg).items()
        }
        out["shared_attn"] = {**_attn_layer_shapes(cfg), **_ffn_shapes(cfg)}
    else:
        out["layers"] = {
            k: (l, *s)
            for k, s in {**_attn_layer_shapes(cfg), **_ffn_shapes(cfg)}.items()
        }
    return out


_INIT_SCALE = {"ln1": 0.0, "ln2": 0.0, "final_norm": 0.0, "norm_w": 0.0}


def init_params(cfg, key) -> dict:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )

    leaves = []
    for i, (path, shape) in enumerate(flat):
        name = path[-1].key
        k = jax.random.fold_in(key, i)
        if any(t in name for t in ("ln1", "ln2", "final_norm", "norm_w")):
            leaves.append(jnp.zeros(shape, cfg.param_dtype))
        elif "dt_bias" in name:
            leaves.append(jnp.log(jnp.expm1(jnp.full(shape, 0.01, jnp.float32))).astype(cfg.param_dtype))
        elif "a_log" in name:
            leaves.append(jnp.log(jnp.ones(shape, jnp.float32)).astype(cfg.param_dtype))
        elif "d_skip" in name:
            leaves.append(jnp.ones(shape, cfg.param_dtype))
        elif name.startswith("b") or "conv_b" in name:
            leaves.append(jnp.zeros(shape, cfg.param_dtype))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 0.02 if "embed" in name or "lm_head" in name else fan_in**-0.5
            leaves.append((jax.random.normal(k, shape, jnp.float32) * std).astype(cfg.param_dtype))
    return jax.tree.unflatten(treedef, leaves)


def abstract_params(cfg) -> dict:
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, cfg.param_dtype),
        param_shapes(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )


def cache_shapes(cfg, batch: int, capacity: int) -> dict:
    """Shapes of the decode cache for a given batch/capacity."""
    out = {}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        a = cfg.num_layers
    elif cfg.family == "hybrid":
        a = cfg.num_layers // cfg.attn_every
    else:
        a = 0
    if a:
        kvshape = (a, batch, capacity, cfg.num_kv_heads, cfg.head_dim)
        out["k"] = kvshape
        out["v"] = kvshape
    if cfg.family in ("ssm", "hybrid"):
        dims = ssm_lib.ssm_dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv)
        m = cfg.num_layers
        out["conv"] = (m, batch, dims["conv_k"] - 1, dims["conv_dim"])
        out["ssd"] = (m, batch, dims["nheads"], dims["headdim"], dims["state"])
    return out


def init_cache(cfg, batch: int, capacity: int, length: int = 0) -> Cache:
    shapes = cache_shapes(cfg, batch, capacity)
    kw = {k: jnp.zeros(s, jnp.float32 if k == "ssd" else cfg.dtype) for k, s in shapes.items()}
    return Cache(length=jnp.int32(length), **kw)


def abstract_cache(cfg, batch: int, capacity: int) -> Cache:
    shapes = cache_shapes(cfg, batch, capacity)
    kw = {
        k: jax.ShapeDtypeStruct(s, jnp.float32 if k == "ssd" else cfg.dtype)
        for k, s in shapes.items()
    }
    return Cache(length=jax.ShapeDtypeStruct((), jnp.int32), **kw)


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------


def train_loss(params, batch, cfg):
    """batch: {tokens|embeds: (B, L[, D]), labels: (B, L)} -> scalar loss."""
    inputs = batch["embeds"] if cfg.embeds_input else batch["tokens"]
    logits, aux, _ = transformer.forward(params, inputs, cfg, mode="train")
    loss = softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return loss + AUX_WEIGHT * aux


def prefill(params, inputs, cfg):
    """Full-sequence forward building a decode cache. Returns (logits, cache)."""
    logits, _, cache = transformer.forward(params, inputs, cfg, mode="prefill")
    return logits, cache


def decode_step(params, token, cache, cfg):
    """One decode step. token: (B, 1) int32. Returns (logits, new cache)."""
    logits, _, cache = transformer.forward(params, token, cfg, mode="decode", cache=cache)
    return logits, cache
