"""Decoder stacks for all assigned families, built for scan-over-layers.

All per-layer parameters are *stacked* on a leading layer axis and the stack
is traversed with ``lax.scan`` — HLO size stays O(1) in depth, which keeps
the 66 multi-pod dry-run compiles tractable and is the standard production
pattern (MaxText does the same). Heterogeneous patterns are handled as:

  * gemma3 5:1 local:global — per-layer ``is_global`` flag rides the scan;
  * zamba2 — homogeneous Mamba2 segments scanned, the *shared* attention
    block (one param set) applied between segments (python loop, 9 calls);
  * MoE — expert weights stacked (L, E, D, F), dispatched inside the scan.

Modes: "train"/"prefill" process full sequences (flash attention / chunked
SSD); "decode" processes one token against a cache.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn_lib
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import dense, embed, rms_norm, rope, swiglu, unembed

__all__ = ["forward", "Cache", "layer_flags"]


class Cache(NamedTuple):
    """Unified decode cache. Attention slots and/or SSM slots may be present.

    k/v: (A, B, S, KV, hd) for the A attention layers of the model
    conv/ssd: (M, B, K-1, C) / (M, B, H, P, N) for the M Mamba layers
    length: () int32 — number of valid tokens already in the cache.
    """

    k: Any = None
    v: Any = None
    conv: Any = None
    ssd: Any = None
    length: Any = None


def _act(x, cfg):
    """Pin activations to (batch, seq)-sharded layout at layer boundaries.

    Without this GSPMD may propagate the params' tensor-parallel shardings
    into the activations and *all-gather the batch* (measured: granite-3-8b
    train_4k ran the full global batch on every device — 16x flop waste).

    With seq_parallel (Megatron-SP) the seq dim additionally shards over the
    model axis, so the per-layer scan carry saved for the backward pass is
    1/model_size the size (granite train_4k: 21GiB -> 1.3GiB per device).
    """
    if not cfg.mesh_dp:
        return x
    seq = None
    if (
        cfg.seq_parallel
        and x.ndim >= 3
        and cfg.mesh_model
        and cfg.mesh_model_size
        and x.shape[1] % cfg.mesh_model_size == 0
    ):
        seq = cfg.mesh_model
    return jax.lax.with_sharding_constraint(
        x, P(tuple(cfg.mesh_dp), seq, *(None,) * (x.ndim - 2))
    )


def _remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def layer_flags(cfg) -> jax.Array | None:
    """Per-layer is_global flags (gemma3 5:1 pattern); None when uniform."""
    if cfg.global_every:
        i = jnp.arange(cfg.num_layers)
        return (i % cfg.global_every) == (cfg.global_every - 1)
    return None


# --------------------------------------------------------------------------
# sub-blocks
# --------------------------------------------------------------------------


def _attn_sublayer(p, x, cfg, *, positions, mode, is_global=None, ck=None, cv=None, length=None):
    """Attention residual branch. Returns (delta, new_k, new_v)."""
    b, l, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["ln1"])
    q = dense(xn, p["wq"], p.get("bq")).reshape(b, l, h, hd)
    k = dense(xn, p["wk"], p.get("bk")).reshape(b, l, kv, hd)
    v = dense(xn, p["wv"], p.get("bv")).reshape(b, l, kv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if mode == "decode":
        # insert at position `length`, then attend over length+1 tokens.
        # All four indices share `length`'s dtype: under enable_x64 the
        # literal zeros would otherwise weaken to int64 and mismatch it.
        zero = jnp.zeros((), length.dtype)
        ck = jax.lax.dynamic_update_slice(ck, k, (zero, length, zero, zero))
        cv = jax.lax.dynamic_update_slice(cv, v, (zero, length, zero, zero))
        o = attn_lib.decode_attention(
            q, ck, cv, length + 1, window=cfg.sliding_window, is_global=is_global
        )
        out_k, out_v = ck, cv
    else:
        o = attn_lib.flash_attention(
            q, k, v,
            causal=True, window=cfg.sliding_window, is_global=is_global,
            kv_chunk=cfg.attn_kv_chunk, unroll=cfg.attn_unroll,
            attn_shard=cfg.attn_shard, dp_axes=cfg.mesh_dp, model_axis=cfg.mesh_model,
        )
        out_k, out_v = k, v
    return dense(o.reshape(b, l, h * hd), p["wo"]), out_k, out_v


def _ff_sublayer(p, x, cfg):
    """FFN residual branch: dense SwiGLU or MoE (+optional dense residual)."""
    xn = rms_norm(x, p["ln2"])
    if cfg.num_experts:
        b, l, d = xn.shape
        ep, cap_axis, groups = None, None, 1
        sizes = dict(cfg.mesh_axis_sizes)
        if cfg.mesh_model and sizes:
            dp_size = 1
            for a in cfg.mesh_dp:
                dp_size *= sizes[a]
            groups = dp_size if (b * l) % dp_size == 0 else 1
            # GShard groups = DP shards; E shards over model when divisible,
            # else per-group capacity takes the model axis.
            msize = sizes[cfg.mesh_model]
            cap_g = max(
                int(cfg.capacity_factor * cfg.top_k * (b * l // groups) / cfg.num_experts),
                cfg.top_k, 1,
            )
            if cfg.num_experts % msize == 0:
                ep = cfg.mesh_model
            elif cap_g % msize == 0:
                cap_axis = cfg.mesh_model
        out = moe_lib.moe_ffn(
            xn.reshape(b * l, d),
            p["router"], p["w_gate"], p["w_up"], p["w_down"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            num_groups=groups, group_axes=tuple(cfg.mesh_dp),
            ep_axis=ep, cap_axis=cap_axis,
        )
        y = out.y.reshape(b, l, d)
        if cfg.dense_residual:
            y = y + swiglu(xn, p["wr_gate"], p["wr_up"], p["wr_down"])
        return y, out.aux_loss
    return swiglu(xn, p["w_gate"], p["w_up"], p["w_down"]), jnp.float32(0.0)


# --------------------------------------------------------------------------
# family forwards
# --------------------------------------------------------------------------


def _fwd_attn_stack(params, x, cfg, *, positions, mode, cache: Cache | None):
    """Dense / MoE / gemma-pattern attention stacks (one scan)."""
    flags = layer_flags(cfg)
    remat = cfg.remat and mode == "train"

    def body(carry, xs):
        x, aux = carry
        x = _act(x, cfg)
        if cache is not None:
            lp, flag, ck, cv = xs
        else:
            lp, flag = xs
            ck = cv = None
        delta, nk, nv = _attn_sublayer(
            lp, x, cfg, positions=positions, mode=mode,
            is_global=None if flags is None else flag,
            ck=ck, cv=cv, length=None if cache is None else cache.length,
        )
        x = x + delta
        ff, aux_l = _ff_sublayer(lp, x, cfg)
        x = x + ff
        # Emitting K/V is only needed when building/updating a cache; in
        # train mode it would stack (L, B, S, KV, hd) for nothing.
        ys = None if mode == "train" else (nk, nv)
        return (x, aux + aux_l), ys

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))

    flags_xs = flags if flags is not None else jnp.zeros((cfg.num_layers,), bool)
    if cache is not None:
        xs = (params["layers"], flags_xs, cache.k, cache.v)
    else:
        xs = (params["layers"], flags_xs)
    if cfg.unroll_layers:  # cost-model mode (see launch/dryrun.py)
        carry = (x, jnp.float32(0.0))
        ys_list = []
        for i in range(cfg.num_layers):
            carry, ys_i = body(carry, jax.tree.map(lambda a: a[i], xs))
            ys_list.append(ys_i)
        (x, aux) = carry
        ys = None if ys_list[0] is None else jax.tree.map(lambda *a: jnp.stack(a), *ys_list)
    else:
        (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    ks, vs = ys if ys is not None else (None, None)
    return x, aux, ks, vs


def _fwd_ssm_stack(params, x, cfg, *, mode, cache: Cache | None):
    """Pure Mamba2 stack (mamba2-2.7b)."""
    remat = cfg.remat and mode == "train"

    if mode == "decode":
        def body(x, xs):
            lp, conv, ssd = xs
            delta, st = ssm_lib.ssm_decode_step(
                {k: v for k, v in lp.items() if k != "ln1"},
                rms_norm(x[:, 0], lp["ln1"]), ssm_lib.SSMState(conv, ssd), cfg
            )
            return x + delta[:, None], (st.conv, st.ssd)

        xs_dec = (params["layers"], cache.conv, cache.ssd)
        if cfg.unroll_layers:
            sts = []
            n_l = jax.tree.leaves(params["layers"])[0].shape[0]
            for i in range(n_l):
                x, st_i = body(x, jax.tree.map(lambda a: a[i], xs_dec))
                sts.append(st_i)
            convs, ssds = jax.tree.map(lambda *a: jnp.stack(a), *sts)
        else:
            x, (convs, ssds) = jax.lax.scan(body, x, xs_dec)
        return x, jnp.float32(0.0), convs, ssds

    def body(x, lp):
        x = _act(x, cfg)
        xn = rms_norm(x, lp["ln1"])
        out = ssm_lib.ssm_forward(
            {k: v for k, v in lp.items() if k != "ln1"}, xn, cfg,
            return_state=(mode == "prefill"),
        )
        if mode == "prefill":
            delta, st = out
            return x + delta, (st.conv, st.ssd)
        return x + out, None

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    if cfg.unroll_layers:
        n_l = jax.tree.leaves(params["layers"])[0].shape[0]
        sts_list = []
        for i in range(n_l):
            x, st_i = body(x, jax.tree.map(lambda a: a[i], params["layers"]))
            sts_list.append(st_i)
        sts = None if sts_list[0] is None else jax.tree.map(lambda *a: jnp.stack(a), *sts_list)
    else:
        x, sts = jax.lax.scan(body, x, params["layers"])
    if mode == "prefill":
        return x, jnp.float32(0.0), sts[0], sts[1]
    return x, jnp.float32(0.0), None, None


def _fwd_hybrid(params, x, cfg, *, positions, mode, cache: Cache | None):
    """Zamba2: Mamba2 segments + ONE shared attention block between segments."""
    every = cfg.attn_every
    n_seg = cfg.num_layers // every
    sp = params["shared_attn"]
    seg_params = params["layers"]  # leaves: (n_seg, every, ...)

    new_convs, new_ssds, new_ks, new_vs = [], [], [], []
    aux = jnp.float32(0.0)
    # Per-LAYER remat inside segments (segment-granularity remat was
    # measured at 149-215GiB/dev: the backward recompute of a whole segment
    # holds every internal SSD buffer at once); the shared attention block
    # is checkpointed on its own below.
    inner_cfg = cfg
    for s in range(n_seg):
        lp_seg = jax.tree.map(lambda a: a[s], seg_params)
        sub_cache = None
        if cache is not None and mode == "decode":
            sub_cache = Cache(
                conv=cache.conv[s * every : (s + 1) * every],
                ssd=cache.ssd[s * every : (s + 1) * every],
                length=cache.length,
            )
        ck = cache.k[s] if (cache is not None and cache.k is not None) else None
        cv = cache.v[s] if (cache is not None and cache.v is not None) else None

        x, _, conv_s, ssd_s = _fwd_ssm_stack(
            {"layers": lp_seg}, x, inner_cfg, mode=mode, cache=sub_cache
        )

        def shared_block(x, ck=ck, cv=cv):
            delta, nk, nv = _attn_sublayer(
                sp, x, cfg, positions=positions, mode=mode,
                ck=ck, cv=cv, length=None if cache is None else cache.length,
            )
            x = x + delta
            ff, aux_l = _ff_sublayer(sp, x, cfg)
            if mode == "train":  # emitting K/V would pin (B, L, KV, hd) x9
                nk = nv = None
            return _act(x + ff, cfg), nk, nv, aux_l

        if cfg.remat and mode == "train":
            shared_block = jax.checkpoint(shared_block, policy=_remat_policy(cfg))
        x, nk, nv, aux_l = shared_block(_act(x, cfg))
        aux = aux + aux_l
        if conv_s is not None:
            new_convs.append(conv_s)
            new_ssds.append(ssd_s)
        if nk is not None:
            new_ks.append(nk)
            new_vs.append(nv)

    ks = jnp.stack(new_ks) if new_ks else None
    vs = jnp.stack(new_vs) if new_vs else None
    convs = jnp.concatenate(new_convs) if new_convs else None
    ssds = jnp.concatenate(new_ssds) if new_ssds else None
    return x, aux, ks, vs, convs, ssds


# --------------------------------------------------------------------------
# public entry
# --------------------------------------------------------------------------


def forward(params, inputs, cfg, *, mode: str, cache: Cache | None = None):
    """Run the stack.

    inputs: int tokens (B, L) or precomputed embeddings (B, L, D) for the
    stubbed [vlm]/[audio] frontends. Returns (logits_f32, aux_loss, Cache|None).
    """
    if inputs.dtype in (jnp.int32, jnp.int64):
        x = embed(inputs, params["embed"], cfg.dtype)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    else:
        x = inputs.astype(cfg.dtype)
    x = _act(x, cfg)
    b, l = x.shape[0], x.shape[1]

    if mode == "decode":
        positions = jnp.broadcast_to(cache.length, (b, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))

    family = cfg.family
    new_cache = None
    if family in ("dense", "moe", "vlm", "audio"):
        x, aux, ks, vs = _fwd_attn_stack(
            params, x, cfg, positions=positions, mode=mode, cache=cache
        )
        if mode == "prefill":
            new_cache = _prefill_attn_cache(ks, vs, cfg, b, l)
        elif mode == "decode":
            new_cache = cache._replace(k=ks, v=vs, length=cache.length + 1)
    elif family == "ssm":
        x, aux, convs, ssds = _fwd_ssm_stack(params, x, cfg, mode=mode, cache=cache)
        if mode == "prefill":
            new_cache = Cache(conv=convs, ssd=ssds, length=jnp.int32(l))
        elif mode == "decode":
            new_cache = cache._replace(conv=convs, ssd=ssds, length=cache.length + 1)
    elif family == "hybrid":
        x, aux, ks, vs, convs, ssds = _fwd_hybrid(
            params, x, cfg, positions=positions, mode=mode, cache=cache
        )
        if mode == "prefill":
            kc = _prefill_attn_cache(ks, vs, cfg, b, l)
            new_cache = Cache(k=kc.k, v=kc.v, conv=convs, ssd=ssds, length=jnp.int32(l))
        elif mode == "decode":
            new_cache = cache._replace(
                k=ks, v=vs, conv=convs, ssd=ssds, length=cache.length + 1
            )
    else:
        raise ValueError(f"unknown family {family}")

    x = _act(rms_norm(x, params["final_norm"]), cfg)
    if mode in ("prefill", "decode"):
        x = x[:, -1:]  # only the last position produces a next-token logit
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x, table)
    return logits, aux, new_cache


def _prefill_attn_cache(ks, vs, cfg, b, l) -> Cache:
    """Stacked per-layer K/V from prefill become the decode cache as-is.

    The cache is sized to (prefill length + decode budget); launch code pads
    to the shape's seq_len via cache_pad.
    """
    pad = cfg.cache_pad
    if pad:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return Cache(k=ks, v=vs, length=jnp.int32(l))
