"""Deterministic fault injection: a seeded ``FaultPlan`` over named sites.

The crash-safety layer is only as trustworthy as the failures it has been
exercised against, so faults are injected *deterministically*: a
``FaultPlan`` carries one seeded RNG per site plus a thread-safe invocation
counter, and every instrumented code path calls ``plan.check(site)`` at the
point where that class of failure would strike. A firing check raises
``InjectedFault`` — the production code must treat it exactly like the real
failure (there is no test-only branch downstream of the raise).

Sites (who calls ``check`` where):

* ``worker_query`` — the serve worker pool, immediately before each engine
  launch (``serve.server.RMQServer``). ``kind="crash"`` additionally kills
  the worker thread after its batch is failed/retried, exercising the
  supervisor restart path.
* ``patch_apply`` — the ``apply_deltas`` stage observer of the online-update
  pipeline (``fault.durable.DurableEngine``): the patch ran, the publish has
  not — the mirrors-diverged-from-published-chain crash the fail-stop +
  journal-replay recovery exists for.
* ``checkpoint_write`` — inside ``checkpoint.store.save`` between the leaf
  writes and the manifest/rename: a torn temp directory that restore must
  ignore.
* ``journal_append`` — mid-record inside ``fault.wal.Journal.append``: a
  ``"crash"`` leaves torn bytes on disk (recovery stops at the last complete
  record); an ``"error"`` is rolled back to the pre-append offset and
  surfaces as a failed update.
* ``rollout_apply`` — the fleet front door (``serve.fleet.RMQFleet``),
  immediately before handing a rollout's update batch to one replica's
  server: a replica crash mid-rollout, exercising the crash -> restore ->
  rejoin-at-fleet-vid path.

``FaultSpec.at`` fires at exact 1-based invocation counts (fully
deterministic regardless of thread interleaving); ``rate`` fires
probabilistically from the per-site seeded stream (deterministic given a
fixed invocation order, statistically reproducible otherwise).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Mapping, NamedTuple, Optional, Tuple, Union

import numpy as np

__all__ = ["FaultPlan", "FaultSpec", "InjectedFault", "SITES"]

SITES: Tuple[str, ...] = (
    "worker_query",
    "patch_apply",
    "checkpoint_write",
    "journal_append",
    "rollout_apply",
)

_KINDS = ("error", "crash")


class InjectedFault(RuntimeError):
    """One injected failure. ``kind="error"`` models a transient fault (the
    operation failed cleanly, a retry may succeed); ``kind="crash"`` models a
    process/thread death at that point (torn on-disk bytes, a dead worker)."""

    def __init__(self, site: str, count: int, kind: str):
        super().__init__(f"injected {kind} fault at {site} (invocation {count})")
        self.site = site
        self.count = count
        self.kind = kind


class FaultSpec(NamedTuple):
    """When one site fires: exact invocation counts and/or a probability."""

    rate: float = 0.0  # per-invocation firing probability
    at: Tuple[int, ...] = ()  # exact 1-based invocation counts that fire
    kind: str = "error"  # "error" (transient) | "crash" (process death)


class FaultPlan:
    """Seeded, thread-safe fault schedule over the named ``SITES``."""

    def __init__(
        self,
        seed: int = 0,
        specs: Optional[Mapping[str, Union[FaultSpec, dict]]] = None,
    ):
        self.seed = int(seed)
        self._specs: Dict[str, FaultSpec] = {}
        for site, spec in (specs or {}).items():
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r}; have {SITES}")
            if isinstance(spec, dict):
                spec = FaultSpec(**spec)
            if spec.kind not in _KINDS:
                raise ValueError(f"fault kind must be one of {_KINDS}, got {spec.kind!r}")
            if not 0.0 <= spec.rate <= 1.0:
                raise ValueError(f"fault rate must be in [0, 1], got {spec.rate}")
            self._specs[site] = spec._replace(at=tuple(int(c) for c in spec.at))
        self._lock = threading.Lock()
        self._hits = {s: 0 for s in SITES}
        self._fired = {s: 0 for s in SITES}
        # One independent stream per site, derived from the plan seed: adding
        # a spec for one site never shifts another site's draw sequence.
        self._rngs = {s: np.random.default_rng([self.seed, i]) for i, s in enumerate(SITES)}

    def check(self, site: str) -> None:
        """Count one invocation of ``site``; raise ``InjectedFault`` if it fires."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; have {SITES}")
        with self._lock:
            self._hits[site] += 1
            count = self._hits[site]
            spec = self._specs.get(site)
            if spec is None:
                return
            fire = count in spec.at or (
                spec.rate > 0.0 and self._rngs[site].random() < spec.rate
            )
            if fire:
                self._fired[site] += 1
                raise InjectedFault(site, count, spec.kind)

    def hook(self, site: str) -> Callable[[], None]:
        """A no-argument closure of ``check(site)`` for single-site seams."""
        return lambda: self.check(site)

    def hits(self) -> Dict[str, int]:
        """Invocations per site so far (fired or not)."""
        with self._lock:
            return dict(self._hits)

    def fired(self) -> Dict[str, int]:
        """Faults actually raised per site so far."""
        with self._lock:
            return dict(self._fired)
