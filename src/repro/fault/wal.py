"""Write-ahead delta journal: seq-numbered, checksummed, torn-tail tolerant.

Durability contract of the online-update path: every coalesced ``DeltaBatch``
is appended (and fsynced) here *before* any engine mirror is touched, so the
update stream survives a process death at any point. Recovery is

    latest checkpoint  +  replay of the journal suffix (seq > checkpoint seq)

which ``fault.durable.DurableEngine`` makes bit-identical to the
never-crashed state: replay skips seqs the checkpoint already covers
(idempotence under repeated restore) and seqs with an abort marker (batches
that were journaled but whose apply failed — replaying them would fail, or
worse, publish a version the original timeline never had).

Record format (little-endian, append-only):

    4s  magic   b"RMQW"
    B   kind    0 = data, 1 = abort marker
    Q   seq     update sequence number (1-based; checkpoint base is seq 0)
    Q   len     payload length in bytes (0 for abort markers)
    I   crc32   of the payload
    len bytes   npz-serialized DeltaBatch (``DeltaBatch.to_bytes``)

A scan stops at the first incomplete/garbled record: bytes after a torn
write are unreachable by construction (a crash mid-append cannot corrupt
records already on disk — it can only leave a partial tail, which the next
append truncates away). Compaction after a checkpoint (``truncate_upto``)
rewrites the suffix through a temp file + fsync + rename, so it is itself
crash-atomic.
"""

from __future__ import annotations

import os
import struct
import threading
import zlib
from typing import Callable, List, Optional, Tuple

from repro.update.deltas import DeltaBatch

from .inject import InjectedFault

__all__ = ["Journal"]

_MAGIC = b"RMQW"
_HDR = struct.Struct("<4sBQQI")  # magic, kind, seq, payload_len, crc32
_DATA, _ABORT = 0, 1


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class Journal:
    """Append-only WAL over ``DeltaBatch`` records.

    ``fault`` is an optional ``check(site)`` callable (a ``FaultPlan``'s
    bound method) fired mid-append at the ``journal_append`` site: a
    ``"crash"`` leaves a torn record on disk exactly like a real process
    death between ``write`` and ``fsync``; an ``"error"`` rolls the file
    back to the pre-append offset (a cleanly failed append).
    """

    def __init__(self, path: str, *, fault: Optional[Callable[[str], None]] = None):
        self.path = path
        self._fault = fault
        self._lock = threading.Lock()
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a+b")
        _, self._end, self._last_seq = self._read_records()

    # -- reading --------------------------------------------------------------

    def _read_records(self) -> Tuple[List[Tuple[int, Optional[DeltaBatch]]], int, int]:
        """(records, valid_end_offset, max_seq) — stops at the torn tail.

        Records are ``(seq, batch)`` with ``batch=None`` for abort markers.
        ``max_seq`` covers aborts too: sequence numbers are never reused,
        even for failed updates, or an old abort marker could shadow a new
        data record at replay.
        """
        try:
            with open(self.path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return [], 0, 0
        recs: List[Tuple[int, Optional[DeltaBatch]]] = []
        off, last = 0, 0
        while off + _HDR.size <= len(data):
            magic, kind, seq, plen, crc = _HDR.unpack_from(data, off)
            if magic != _MAGIC or kind not in (_DATA, _ABORT):
                break
            end = off + _HDR.size + plen
            if end > len(data):
                break  # torn write: the record never finished
            payload = data[off + _HDR.size : end]
            if kind == _DATA:
                if zlib.crc32(payload) != crc:
                    break  # garbled payload: treat like a torn tail
                recs.append((int(seq), DeltaBatch.from_bytes(payload)))
            else:
                recs.append((int(seq), None))
            off = end
            last = max(last, int(seq))
        return recs, off, last

    def scan(self) -> List[Tuple[int, Optional[DeltaBatch]]]:
        """All complete records in order; ``None`` batch = abort marker."""
        recs, _, _ = self._read_records()
        return recs

    def replay(self, after_seq: int) -> List[Tuple[int, DeltaBatch]]:
        """Data records to re-apply on restore, in order.

        Drops seqs the checkpoint covers (``<= after_seq``), seqs with an
        abort marker anywhere in the journal, and duplicates — so replaying
        a journal any number of times converges on the same state.
        """
        recs = self.scan()
        aborted = {seq for seq, batch in recs if batch is None}
        out: List[Tuple[int, DeltaBatch]] = []
        seen = set()
        for seq, batch in recs:
            if batch is None or seq <= after_seq or seq in aborted or seq in seen:
                continue
            seen.add(seq)
            out.append((seq, batch))
        return out

    @property
    def last_seq(self) -> int:
        """Highest sequence number on disk (data or abort; 0 = empty)."""
        with self._lock:
            return self._last_seq

    # -- writing --------------------------------------------------------------

    def append(self, seq: int, batch: DeltaBatch) -> None:
        """Durably append one data record (flush + fsync before returning)."""
        payload = batch.to_bytes()
        hdr = _HDR.pack(_MAGIC, _DATA, seq, len(payload), zlib.crc32(payload))
        self._write_record(hdr, payload, seq)

    def abort(self, seq: int) -> None:
        """Mark ``seq`` as journaled-but-not-applied: replay will skip it."""
        self._write_record(_HDR.pack(_MAGIC, _ABORT, seq, 0, 0), b"", seq)

    def _write_record(self, hdr: bytes, payload: bytes, seq: int) -> None:
        with self._lock:
            f = self._f
            # Discard any torn tail a previous crash left: appending after it
            # would strand the new record behind unparseable bytes.
            f.truncate(self._end)
            f.seek(self._end)
            half = len(payload) // 2
            try:
                f.write(hdr)
                f.write(payload[:half])
                if self._fault is not None:
                    # Mid-record: a "crash" here is a torn write on disk.
                    self._fault("journal_append")
                f.write(payload[half:])
                f.flush()
                os.fsync(f.fileno())
            except InjectedFault as e:
                f.flush()
                if e.kind != "crash":
                    f.truncate(self._end)  # transient error: clean rollback
                raise
            except BaseException:
                f.flush()
                f.truncate(self._end)
                raise
            self._end += len(hdr) + len(payload)
            self._last_seq = max(self._last_seq, int(seq))

    def truncate_upto(self, seq: int) -> None:
        """Compact away records with ``seq <=`` the given checkpoint seq.

        Atomic (write temp, fsync, rename): a crash mid-compaction leaves
        either the old journal or the new one, never a mix. Abort markers
        above the checkpoint are preserved — replay still needs them.
        """
        with self._lock:
            recs, _, _ = self._read_records()
            tmp = self.path + ".tmp"
            with open(tmp, "wb") as g:
                for s, batch in recs:
                    if s <= seq:
                        continue
                    if batch is None:
                        g.write(_HDR.pack(_MAGIC, _ABORT, s, 0, 0))
                    else:
                        payload = batch.to_bytes()
                        g.write(_HDR.pack(_MAGIC, _DATA, s, len(payload), zlib.crc32(payload)))
                        g.write(payload)
                g.flush()
                os.fsync(g.fileno())
            self._f.close()
            os.replace(tmp, self.path)
            _fsync_dir(os.path.dirname(self.path) or ".")
            self._f = open(self.path, "a+b")
            _, self._end, tail_seq = self._read_records()
            # Numbering continues past compacted records: seqs are never reused.
            self._last_seq = max(self._last_seq, tail_seq, int(seq))

    def close(self) -> None:
        with self._lock:
            self._f.close()
