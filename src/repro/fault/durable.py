"""DurableEngine: WAL-journaled, checkpointable wrapper over an OnlineEngine.

The crash-safety contract, end to end:

* **Journal before apply.** Every update batch is coalesced, assigned the
  next sequence number, and durably appended to the write-ahead journal
  (``fault.wal.Journal``) *before* any engine mirror is touched. A process
  death at any later point loses nothing: the batch replays on restore.
* **Atomic checkpoints.** ``checkpoint()`` snapshots the engine's structure
  leaves + version id + covered seq through
  ``checkpoint.store.save_snapshot`` (write-temp-fsync-rename) and then
  compacts the journal up to that seq. A base checkpoint is written at
  construction, so restore always has a floor.
* **Restore = checkpoint + journal suffix.** ``DurableEngine.restore(root)``
  loads the latest complete checkpoint, reconstructs the engine
  (``OnlineEngine.from_snapshot`` — instant leaf re-seat for single-host
  engines, deterministic BuildPlan re-run for mesh engines) and replays
  journal records with ``seq >`` the checkpoint's. Replay is idempotent
  (seq dedup) and skips aborted seqs, so the result is bit-identical to the
  never-crashed state and version ids continue the original timeline.
* **Poison clears on recovery.** A mid-patch failure fail-stops the inner
  engine (``update.EnginePoisoned`` carries the cause + failing seq) and the
  failing seq gets an abort marker; ``recover()`` re-restores in place —
  the replayed engine skips the aborted update and serves cleanly.

``DurableEngine`` quacks like an ``OnlineEngine`` for serving
(``pin``/``release``/``query``/``apply``/``n``/``current_vid``), so
``serve.RMQServer(online=...)`` takes either interchangeably.
"""

from __future__ import annotations

import os
import threading
from typing import Callable, Optional

from repro import checkpoint as checkpoint_mod
from repro.obs import trace as obs_trace
from repro.obs.metrics import default_registry
from repro.update.deltas import DeltaLog
from repro.update.engines import OnlineEngine

from .wal import Journal

__all__ = ["DurableEngine"]

_CKPT_SUBDIR = "ckpt"
_JOURNAL_FILE = "journal.wal"


def _fault_fn(fault) -> Optional[Callable[[str], None]]:
    """Accept a FaultPlan or a bare ``check(site)`` callable."""
    if fault is None:
        return None
    return fault.check if hasattr(fault, "check") else fault


class DurableEngine:
    """Crash-safe shell around one ``OnlineEngine`` rooted at a directory.

    Layout: ``<root>/journal.wal`` + ``<root>/ckpt/step_<seq>/``. Use
    ``create`` for a fresh engine, ``restore`` after a crash; the plain
    constructor wraps an already-built engine (seq state is taken from the
    journal on disk).
    """

    def __init__(self, online: OnlineEngine, root: str, *, fault=None, _seq: int = 0):
        os.makedirs(root, exist_ok=True)
        self.online = online
        self.root = root
        self._fault = _fault_fn(fault)
        self.journal = Journal(os.path.join(root, _JOURNAL_FILE), fault=self._fault)
        self._lock = threading.Lock()
        # Seqs are never reused — count aborts and compacted records too, or
        # a recovered engine could shadow a fresh update behind a stale abort
        # marker.
        self._seq = max(int(_seq), self.journal.last_seq)
        self.replayed = 0  # journal records re-applied by the last restore

    # -- construction ---------------------------------------------------------

    @classmethod
    def create(
        cls,
        name: str,
        x,
        root: str,
        *,
        mesh=None,
        axis_names=None,
        fault=None,
        **build_kw,
    ) -> "DurableEngine":
        """Build engine ``name`` over ``x`` with durability rooted at ``root``."""
        online = OnlineEngine(name, x, mesh=mesh, axis_names=axis_names, **build_kw)
        d = cls(online, root, fault=fault)
        if checkpoint_mod.latest_step(d.ckpt_dir) is None:
            d.checkpoint()  # durable base: restore always has a floor
        return d

    @classmethod
    def restore(
        cls, root: str, *, mesh=None, axis_names=None, fault=None
    ) -> "DurableEngine":
        """Latest checkpoint + journal-suffix replay -> a consistent engine.

        Bit-identical to the never-crashed state: the checkpoint was taken
        under the apply lock, replayed batches are exactly the journaled
        suffix in seq order (deduped, aborts skipped), and each replayed
        apply runs the same patch path the original did. Idempotent —
        restoring twice (or restoring a restored root) converges on the same
        state and seq.
        """
        ckpt = os.path.join(root, _CKPT_SUBDIR)
        arrays, meta, _ = checkpoint_mod.load_snapshot(ckpt)
        online = OnlineEngine.from_snapshot(arrays, meta, mesh=mesh, axis_names=axis_names)
        d = cls(online, root, fault=fault, _seq=int(meta["seq"]))
        tr = obs_trace.get_tracer()
        with tr.span("restore", attrs={"root": root} if tr.enabled else None):
            for seq, batch in d.journal.replay(after_seq=int(meta["seq"])):
                online.apply(batch, seq=seq)
                d.replayed += 1
        reg = default_registry()
        reg.counter("restores_total").inc()
        reg.counter("restore_replays_total").inc(d.replayed)
        return d

    def recover(self, *, mesh=None, axis_names=None) -> int:
        """In-place crash recovery; returns the number of replayed records.

        Replaces the inner engine with a restore of this root — the
        supported way to clear a poisoned (fail-stopped) applier: the failed
        update was abort-marked, so the replayed engine lands on the last
        published version and accepts new updates again.
        """
        with self._lock:
            fresh = DurableEngine.restore(self.root, mesh=mesh, axis_names=axis_names)
            fresh.journal.close()
            self.online = fresh.online
            self._seq = max(self._seq, fresh._seq)
            self.replayed = fresh.replayed
            return fresh.replayed

    @property
    def ckpt_dir(self) -> str:
        return os.path.join(self.root, _CKPT_SUBDIR)

    @property
    def seq(self) -> int:
        """Sequence number of the last journaled update (0 = none yet)."""
        with self._lock:
            return self._seq

    # -- durability -----------------------------------------------------------

    def apply(self, deltas, *, observer: Optional[Callable] = None):
        """Journal the coalesced batch durably, then apply it.

        The WAL append (fsynced) happens before the first mirror write, so a
        crash anywhere inside the apply loses nothing — restore replays the
        batch. If the apply itself fails, the seq is abort-marked: replay
        must not re-attempt a batch that already failed deterministically
        (malformed bounds) or re-poison a restored engine. Should the abort
        write die too (a real crash), replay re-applies the batch and
        reaches the same outcome — apply is deterministic.
        """
        tr = obs_trace.get_tracer()
        with self._lock:
            if isinstance(deltas, DeltaLog):
                batch = deltas.coalesce(self.online.n, dtype=self.online.dtype)
            else:
                batch = deltas
            seq = self._seq + 1
            with tr.span("journal_append", attrs={"seq": seq} if tr.enabled else None):
                self.journal.append(seq, batch)  # WAL: durable BEFORE any mutation
            default_registry().counter("wal_appends_total").inc()
            self._seq = seq
            obs = self._observer(observer)
            try:
                return self.online.apply(batch, observer=obs, seq=seq)
            except BaseException:
                try:
                    self.journal.abort(seq)
                    default_registry().counter("wal_aborts_total").inc()
                except BaseException:
                    pass  # crash-during-abort: at-least-once replay, see above
                raise

    def _observer(self, user_obs: Optional[Callable]) -> Optional[Callable]:
        """Compose the stage observers: user first, then tracing, then the
        patch_apply fault site.

        The fault site fires after the ``apply_deltas`` stage (mirrors
        patched) and before ``publish`` — the mirrors-diverged-from-
        published-chain window the fail-stop + restore machinery exists for.
        The trace marker lands at the same boundary so an exported trace
        shows exactly where injection can strike; injection firing LAST means
        the user observer and the trace marker still see a stage that
        completed, even on the apply that gets killed.
        """
        user_fires = user_obs is not None
        trace_fires = obs_trace.get_tracer().enabled
        fault_fires = self._fault is not None
        if not (trace_fires or fault_fires):
            return user_obs

        def obs(stage: str, state: dict):
            if user_fires:
                user_obs(stage, state)
            if stage == "apply_deltas":
                if trace_fires:
                    obs_trace.get_tracer().instant(
                        "patch_applied", attrs={"seq": self._seq}
                    )
                if fault_fires:
                    self._fault("patch_apply")

        return obs

    def checkpoint(self) -> dict:
        """Snapshot the current version atomically; compact the journal.

        Returns the checkpoint meta. Refuses on a poisoned engine
        (``snapshot()`` raises — a diverged mirror must never become the
        durable base). If the checkpoint write itself fails, the journal is
        left uncompacted: restore falls back to the previous checkpoint plus
        a longer replay suffix, still exact.
        """
        tr = obs_trace.get_tracer()
        with self._lock:
            with tr.span("checkpoint", attrs={"seq": self._seq} if tr.enabled else None):
                arrays, meta = self.online.snapshot()
                meta["seq"] = self._seq
                checkpoint_mod.save_snapshot(
                    self.ckpt_dir, self._seq, arrays, meta, fault=self._fault
                )
                self.journal.truncate_upto(self._seq)
            default_registry().counter("checkpoints_total").inc()
            return meta

    def close(self) -> None:
        self.journal.close()

    # -- OnlineEngine serving surface -----------------------------------------

    @property
    def name(self) -> str:
        return self.online.name

    @property
    def spec(self):
        return self.online.spec

    @property
    def plan(self):
        return self.online.plan

    @property
    def store(self):
        return self.online.store

    @property
    def n(self) -> int:
        return self.online.n

    @property
    def current_vid(self) -> int:
        return self.online.current_vid

    @property
    def dtype(self):
        return self.online.dtype

    @property
    def poisoned(self) -> bool:
        return self.online.poisoned

    def pin(self):
        return self.online.pin()

    def release(self, vid: int) -> None:
        self.online.release(vid)

    def query(self, state, l, r):
        return self.online.query(state, l, r)
