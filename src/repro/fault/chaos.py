"""Chaos soak: mutate-while-serving under a seeded fault plan, oracle-verified.

The end-to-end crash-safety gate. One soak drives an ``RMQServer`` over a
``DurableEngine`` while a deterministic ``FaultPlan`` injects failures at
every seam the subsystem defends:

* **worker_query crashes** — a launch dies AND takes its worker thread with
  it; the supervisor restarts the slot, the batch's requests retry.
* **patch_apply errors** — an update fails after the mirrors were patched
  (the diverged-state window); the engine fail-stops, the journaled seq is
  abort-marked, and the soak recovers in place (checkpoint + journal-suffix
  replay) before resubmitting.
* **checkpoint_write errors** — a mid-soak checkpoint dies after its leaf
  files are written but before the manifest; the torn temp directory is
  ignored and the journal stays uncompacted, so restore still works.

Every query response is verified against a host-side oracle **pinned to the
version it was answered against** (``RequestResult.version``), so a stale
answer, a torn update, or a mixed-version batch is caught as a mismatch —
not averaged away. After the traffic the live engine is abandoned
(simulated crash: only the on-disk root survives) and restored; the soak
asserts the restored structure is bit-identical to the live one, equals a
from-scratch rebuild of the oracle array, and keeps answering correctly.

Run it standalone (the check.sh chaos gate does, on 8 fake devices)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.fault.chaos --engine sharded_hybrid --seed 7

Not imported from ``repro.fault`` — this module pulls in ``repro.serve``.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time
from typing import NamedTuple, Optional

import numpy as np

import jax

from repro.fault.durable import DurableEngine
from repro.fault.inject import FaultPlan, FaultSpec
from repro.serve import RMQServer, ServeConfig
from repro.update import DeltaLog
from repro.update.engines import OnlineEngine, online_names

__all__ = ["SoakReport", "default_plan", "run_soak", "main"]


def _struct_leaves(online) -> list:
    """The current version's array leaves (callable leaves skipped)."""
    return [
        np.asarray(leaf)
        for leaf in jax.tree_util.tree_leaves(online.store.current.state)
        if hasattr(leaf, "shape")
    ]


def _leaves_equal(a: list, b: list) -> bool:
    return len(a) == len(b) and all(
        x.shape == y.shape and np.array_equal(x, y) for x, y in zip(a, b)
    )


class SoakReport(NamedTuple):
    engine: str
    seed: int
    requests: int  # client requests submitted
    queries: int  # individual RMQs across those requests
    updates_applied: int  # successfully published update batches
    update_failures: int  # injected apply failures (each recovered + resubmitted)
    recoveries: int  # in-place DurableEngine.recover() calls
    failed_checkpoints: int  # injected checkpoint-write failures
    oracle_mismatches: int  # responses disagreeing with their version's oracle
    lost_requests: int  # requests that failed instead of answering
    worker_restarts: int
    retried_requests: int
    degraded_launches: int
    breaker_trips: int
    restore_replayed: int  # journal records replayed by the post-crash restore
    restore_vid_ok: bool  # restored version id continues the live timeline
    restore_identical: bool  # restored leaves == live leaves, bit for bit
    restore_equals_rebuild: bool  # restored leaves == from-scratch rebuild
    restore_serves: bool  # restored server answers oracle-correct
    elapsed_s: float

    @property
    def ok(self) -> bool:
        return (
            self.oracle_mismatches == 0
            and self.lost_requests == 0
            and self.restore_vid_ok
            and self.restore_identical
            and self.restore_equals_rebuild
            and self.restore_serves
        )

    def summary(self) -> str:
        return (
            f"[{'OK' if self.ok else 'FAIL'}] {self.engine} seed={self.seed}: "
            f"{self.requests} reqs / {self.queries} RMQs, "
            f"{self.updates_applied} updates ({self.update_failures} injected apply "
            f"failures -> {self.recoveries} recoveries), "
            f"{self.failed_checkpoints} failed checkpoints, "
            f"{self.worker_restarts} worker restarts, {self.retried_requests} retried, "
            f"{self.degraded_launches} degraded, breaker x{self.breaker_trips}; "
            f"mismatches={self.oracle_mismatches} lost={self.lost_requests}; "
            f"restore: replayed={self.restore_replayed} vid_ok={self.restore_vid_ok} "
            f"identical={self.restore_identical} rebuild={self.restore_equals_rebuild} "
            f"serves={self.restore_serves}; {self.elapsed_s:.1f}s"
        )


def default_plan(seed: int) -> FaultPlan:
    """The standard soak plan: every defended seam fires at least once.

    ``worker_query`` crashes probabilistically (supervisor + retry path);
    ``patch_apply`` fails exactly the 2nd apply (poison -> recover path);
    ``checkpoint_write`` fails exactly the 2nd snapshot — the base checkpoint
    at create() is invocation 1, so the mid-soak checkpoint dies first try.
    """
    return FaultPlan(
        seed,
        {
            "worker_query": FaultSpec(rate=0.04, kind="crash"),
            "patch_apply": FaultSpec(at=(2,)),
            "checkpoint_write": FaultSpec(at=(2,)),
        },
    )


def _mutate(rng: np.random.Generator, cur: np.ndarray):
    """One random update batch + the expected post-update oracle array."""
    n = cur.shape[0]
    log = DeltaLog()
    new = cur.copy()
    op = rng.integers(0, 3)
    if op == 0:  # point writes
        for i in rng.integers(0, n, size=int(rng.integers(1, 5))):
            v = float(rng.standard_normal())
            log.point(int(i), v)
            new[int(i)] = np.float32(v)
    elif op == 1:  # constant range fill
        l = int(rng.integers(0, n))
        r = min(n - 1, l + int(rng.integers(0, 64)))
        v = float(rng.standard_normal())
        log.fill(l, r, v)
        new[l : r + 1] = np.float32(v)
    else:  # append
        tail = rng.standard_normal(int(rng.integers(1, 33))).astype(np.float32)
        log.append(tail)
        new = np.concatenate([new, tail])
    return log, new


def run_soak(
    *,
    engine: str = "hybrid",
    n: int = 1 << 13,
    requests: int = 120,
    updates: int = 10,
    qbatch: int = 4,
    seed: int = 0,
    root: Optional[str] = None,
    workers: int = 2,
    mesh=None,
    axis_names=None,
    plan: Optional[FaultPlan] = None,
    log=None,
) -> SoakReport:
    """Run one seeded chaos soak; see the module docstring for what it proves.

    Deterministic given (seed, engine, n, requests, updates, qbatch): the
    same faults fire at the same invocations and the same mutations hit the
    same indices. Only thread interleaving varies — which is the point: the
    correctness conditions must hold under every interleaving.
    """
    say = log if log is not None else (lambda *_: None)
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    plan = plan if plan is not None else default_plan(seed)

    owned_root = root is None
    root = root if root is not None else tempfile.mkdtemp(prefix="rmq-chaos-")
    durable = DurableEngine.create(
        engine, x, root, mesh=mesh, axis_names=axis_names, fault=plan
    )
    cfg = ServeConfig(
        workers=workers,
        deadline_s=5e-4,
        max_retries=12,  # crashes are retryable: nothing may be lost
        breaker_threshold=4,
        breaker_cooldown_s=0.02,
    )
    srv = RMQServer(online=durable, fault_plan=plan, config=cfg).start()

    # Host-side oracle, one array per published version id.
    cur = x.copy()
    expected = {durable.current_vid: cur.copy()}
    updates_applied = update_failures = recoveries = failed_ckpts = 0
    mismatches = lost = nreq = nq = 0
    update_every = max(1, requests // max(updates, 1))
    pending = []  # (l, r, future)

    def drain():
        nonlocal mismatches, lost, nreq, nq
        for l, r, fut in pending:
            nreq += 1
            nq += l.size
            try:
                res = fut.result(timeout=120)
            except Exception as e:
                lost += 1
                say(f"LOST request: {e!r}")
                continue
            ox = expected.get(res.version)
            if ox is None:  # a version we never published: silently wrong
                mismatches += l.size
                say(f"unknown version {res.version}")
                continue
            for i in range(l.size):
                seg = ox[l[i] : r[i] + 1]
                if res.idx[i] != l[i] + int(np.argmin(seg)) or not np.array_equal(
                    res.val[i], seg[res.idx[i] - l[i]]
                ):
                    mismatches += 1
        pending.clear()

    for step in range(requests):
        if updates and step and step % update_every == 0:
            # Updates are barriers: drain outstanding queries first so the
            # oracle never races the publish (responses pin their version,
            # but waiting here keeps the driver simple and deterministic).
            drain()
            dlog, new = _mutate(rng, cur)
            for attempt in range(2):
                try:
                    res = srv.submit_update(dlog).result(timeout=120)
                    break
                except Exception as e:
                    # Injected patch_apply failure: the engine fail-stopped
                    # and the seq was abort-marked. Recover in place
                    # (checkpoint + journal-suffix replay) and resubmit.
                    update_failures += 1
                    say(f"update failed ({e!r}); recovering")
                    durable.recover(mesh=mesh, axis_names=axis_names)
                    recoveries += 1
            else:
                raise RuntimeError("update failed twice; recovery did not clear it")
            cur = new
            expected[res.version] = cur.copy()
            updates_applied += 1
        if step == requests // 2:
            # Mid-soak checkpoint. The plan's checkpoint_write site may kill
            # it (torn temp dir, journal uncompacted) — restore must not care.
            drain()
            try:
                durable.checkpoint()
            except Exception as e:
                failed_ckpts += 1
                say(f"checkpoint failed ({e!r}); journal stays authoritative")
        nmax = cur.shape[0]
        l = rng.integers(0, nmax, qbatch).astype(np.int32)
        r = np.minimum(nmax - 1, l + rng.integers(0, nmax // 4, qbatch)).astype(np.int32)
        pending.append((l, r, srv.submit(l, r)))
    drain()

    st = srv.stats()
    pre_vid = durable.current_vid
    pre_leaves = _struct_leaves(durable.online)
    srv.close()
    # Simulated crash: abandon the live engine — only the on-disk root
    # (checkpoints + journal) survives into the restore.
    durable.close()

    restored = DurableEngine.restore(root, mesh=mesh, axis_names=axis_names)
    restore_vid_ok = restored.current_vid == pre_vid
    post_leaves = _struct_leaves(restored.online)
    restore_identical = _leaves_equal(pre_leaves, post_leaves)
    rebuilt = OnlineEngine(engine, expected[pre_vid], mesh=mesh, axis_names=axis_names)
    restore_equals_rebuild = _leaves_equal(post_leaves, _struct_leaves(rebuilt))

    # The restored engine must serve, not just compare equal.
    restore_serves = True
    srv2 = RMQServer(online=restored, config=ServeConfig(workers=1, deadline_s=5e-4)).start()
    ox = expected[pre_vid]
    l = rng.integers(0, ox.shape[0], 8).astype(np.int32)
    r = np.minimum(ox.shape[0] - 1, l + rng.integers(0, 256, 8)).astype(np.int32)
    try:
        res = srv2.submit(l, r).result(timeout=120)
        for i in range(8):
            seg = ox[l[i] : r[i] + 1]
            if res.idx[i] != l[i] + int(np.argmin(seg)):
                restore_serves = False
    except Exception:
        restore_serves = False
    srv2.close()
    restored.close()
    if owned_root:
        shutil.rmtree(root, ignore_errors=True)

    return SoakReport(
        engine=engine,
        seed=seed,
        requests=nreq,
        queries=nq,
        updates_applied=updates_applied,
        update_failures=update_failures,
        recoveries=recoveries,
        failed_checkpoints=failed_ckpts,
        oracle_mismatches=mismatches,
        lost_requests=lost,
        worker_restarts=st.worker_restarts,
        retried_requests=st.retried_requests,
        degraded_launches=st.degraded_launches,
        breaker_trips=st.breaker_trips,
        restore_replayed=restored.replayed,
        restore_vid_ok=restore_vid_ok,
        restore_identical=restore_identical,
        restore_equals_rebuild=restore_equals_rebuild,
        restore_serves=restore_serves,
        elapsed_s=time.perf_counter() - t0,
    )


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description="seeded chaos soak over the crash-safe serve stack")
    p.add_argument("--engine", default="hybrid", choices=sorted(online_names()))
    p.add_argument("--n", type=int, default=1 << 13)
    p.add_argument("--requests", type=int, default=120)
    p.add_argument("--updates", type=int, default=10)
    p.add_argument("--qbatch", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--root", default=None, help="durability root (default: temp dir)")
    p.add_argument("--json", default=None, help="write the report as JSON here")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    mesh = axis_names = None
    from repro.core import registry

    if registry.get(args.engine).needs_mesh:
        mesh, axis_names = registry.default_mesh()
        if not args.quiet:
            print(f"mesh over {len(jax.devices())} devices: {mesh.shape}")

    report = run_soak(
        engine=args.engine,
        n=args.n,
        requests=args.requests,
        updates=args.updates,
        qbatch=args.qbatch,
        seed=args.seed,
        workers=args.workers,
        root=args.root,
        mesh=mesh,
        axis_names=axis_names,
        log=None if args.quiet else print,
    )
    print(report.summary())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report._asdict(), f, indent=2, default=str)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
