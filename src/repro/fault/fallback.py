"""Degraded fallback engine: a pure-jnp sparse table per pinned version.

When the serve circuit breaker opens (the primary engine pool keeps
failing), queries route here instead of erroring: correct answers, slower
path. The fallback builds a plain ``sparse_table`` — no Pallas kernels, no
mesh, no shared mutable state with the primary — from the pinned version's
logical host array (``update.Version.x_host``), so even mid-mutation traffic
is answered against exactly its snapshot. An LRU of a few versions bounds
the rebuild cost under version churn; launch shapes are the batcher's
power-of-two buckets, so the jit cache stays bounded like the primary's.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sparse_table
from repro.core.sparse_table import SparseTable


def _query(table: SparseTable, l, r):
    idx = sparse_table.query(table, l, r)
    return idx, table.x[idx]


_query_jit = jax.jit(_query)

__all__ = ["DegradedFallback"]


class DegradedFallback:
    """Correct-but-slower query engine for breaker-open serving.

    ``query(ver, l, r)`` answers against version ``ver`` (an
    ``update.Version`` with ``x_host``); ``ver=None`` uses the static array
    the fallback was constructed over (non-online servers).
    """

    def __init__(self, x=None, *, cache_versions: int = 4):
        self._static: Optional[SparseTable] = (
            sparse_table.build(jnp.asarray(x)) if x is not None else None
        )
        self._cache: "OrderedDict[int, SparseTable]" = OrderedDict()
        self._max = int(cache_versions)
        self._lock = threading.Lock()

    def _table_for(self, ver) -> SparseTable:
        with self._lock:
            table = self._cache.get(ver.vid)
            if table is not None:
                self._cache.move_to_end(ver.vid)
                return table
        if ver.x_host is None:
            raise RuntimeError(
                f"version {ver.vid} carries no host array; the degraded "
                f"fallback needs Version.x_host to build from"
            )
        table = sparse_table.build(jnp.asarray(ver.x_host))
        with self._lock:
            self._cache[ver.vid] = table
            while len(self._cache) > self._max:
                self._cache.popitem(last=False)
        return table

    def query(self, ver, l, r):
        if ver is None:
            if self._static is None:
                raise RuntimeError(
                    "degraded fallback has no static array and no pinned version"
                )
            table = self._static
        else:
            table = self._table_for(ver)
        return _query_jit(table, jnp.asarray(l), jnp.asarray(r))
