"""Crash-safety subsystem (DESIGN.md §10): WAL, checkpoints, fault injection.

Serving state must survive process death and misbehaving components without
losing an acknowledged update or returning a wrong answer:

* ``inject`` — deterministic seeded fault plans (``FaultPlan``) with four
  sites: ``worker_query``, ``patch_apply``, ``checkpoint_write``,
  ``journal_append``. Everything else in this package takes an optional
  plan and fires its site hooks at the exact instants the machinery is
  most exposed.
* ``wal`` — the append-only, checksummed, seq-numbered delta journal.
  Torn tails (a crash mid-append) are detected and dropped on scan;
  replay dedups seqs and skips abort markers.
* ``durable`` — ``DurableEngine``: journal-before-apply over any updatable
  ``OnlineEngine``, atomic structure checkpoints, restore = checkpoint +
  journal-suffix replay (bit-identical to the never-crashed state).
* ``fallback`` — ``DegradedFallback``: the pure-jnp sparse-table engine
  the serve circuit breaker routes to while the primary pool is failing —
  correct answers, slower path.
* ``chaos`` (not imported here — it pulls in ``repro.serve``; run it as
  ``python -m repro.fault.chaos``) — the seeded mutate-while-serving soak
  that kills workers, fails patches, and crash-restores mid-stream while
  oracle-verifying every response against its pinned version.
"""

from .inject import SITES, FaultPlan, FaultSpec, InjectedFault
from .wal import Journal
from .durable import DurableEngine
from .fallback import DegradedFallback

__all__ = [
    "SITES",
    "DegradedFallback",
    "DurableEngine",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "Journal",
]
