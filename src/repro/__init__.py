"""repro — RTXRMQ-TPU: batched Range Minimum Queries as a distributed JAX
service, plus the multi-pod LM substrate it is embedded in (see README.md).

Reproduction of Meneses, Navarro, Ferrada, Quezada — "Accelerating Range
Minimum Queries with Ray Tracing Cores" (2023), adapted to TPU (DESIGN.md).
"""

__version__ = "1.0.0"
