"""RMQ inside the data pipeline: pack a document stream into fixed-length
training sequences using range-max (RMQ on negated free space) bin selection.

    PYTHONPATH=src python examples/packing_pipeline.py
"""

import numpy as np

from repro.data import packing, pipeline


def main():
    seq_len = 2048
    lengths = pipeline.synthetic_documents(4000, seq_len, seed=7)
    assign, free = packing.pack_documents(lengths, seq_len)

    used_bins = int((free < seq_len).sum())
    total_tokens = int(np.minimum(lengths, seq_len).sum())
    lower_bound = -(-total_tokens // seq_len)
    efficiency = total_tokens / (used_bins * seq_len)
    print(
        f"packed {len(lengths)} docs ({total_tokens} tokens) into {used_bins} "
        f"sequences of {seq_len} (lower bound {lower_bound}); "
        f"fill efficiency {efficiency:.1%}"
    )
    assert (assign >= 0).all()
    assert efficiency > 0.7


if __name__ == "__main__":
    main()
