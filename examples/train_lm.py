"""End-to-end training driver: a ~100M-param qwen2-family model trained for a
few hundred steps on the synthetic pipeline with checkpoint/restart enabled.

    PYTHONPATH=src python examples/train_lm.py --steps 300

(Defaults are sized for the CPU container; on a TPU pod pass
--production-mesh via repro.launch.train instead.)
"""

import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config
from repro.launch.mesh import make_mesh, set_mesh
from repro.models import model
from repro.optim import adamw
from repro.train import runner as runner_lib
from repro.train.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)

    # ~100M params: qwen2 family, 10 layers, d_model 640, vocab 50k
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"),
        num_layers=10, d_model=640, num_heads=10, num_kv_heads=2, head_dim=64,
        d_ff=2560, vocab_size=50_000, remat=False, attn_kv_chunk=128,
        dtype=jax.numpy.float32, param_dtype=jax.numpy.float32,
        attn_shard="heads",
    )
    n = cfg.param_count()
    print(f"model: {n/1e6:.1f}M params")

    mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
    with set_mesh(mesh):
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        step_fn, _ = make_train_step(
            cfg, mesh, lr_fn=adamw.cosine_schedule(3e-4, 20, args.steps),
            batch=args.batch, seq_len=args.seq_len,
        )
        rcfg = runner_lib.RunnerConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100, seed=0,
            data_period=8,  # cycle 8 synthetic batches so the loss is learnable
        )
        report = runner_lib.run_training(
            step_fn, params, opt, cfg, args.batch, args.seq_len, rcfg
        )
    print(
        f"trained {report.steps_done} steps: loss {report.losses[0]:.3f} -> "
        f"{report.losses[-1]:.3f} (restarts={report.restarts})"
    )
    assert report.losses[-1] < report.losses[0]


if __name__ == "__main__":
    main()
