"""End-to-end serving driver: distributed RMQ engines over a device mesh,
serving batched queries under the paper's three range distributions.

Runs the plain mesh-sharded blocked engine on the small/large regimes, then
the sharded range-adaptive hybrid (``--engine sharded_hybrid``) on a mixed
regime — in both its structure-sharded and batch-sharded (``--qshard``)
modes. Run with multiple fake devices to exercise the collective merges:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_rmq.py
"""

import sys

from repro.launch import serve


def _run(*extra):
    sys.argv = [sys.argv[0], "--n", str(1 << 20), "--batch", "8192",
                "--batches", "8", *extra]
    serve.main()


def main():
    _run("--dist", "small")
    _run("--dist", "large")
    _run("--dist", "medium", "--engine", "sharded_hybrid")
    _run("--dist", "medium", "--engine", "sharded_hybrid", "--qshard")


if __name__ == "__main__":
    main()
