"""End-to-end serving driver: distributed RMQ engine over a device mesh,
serving batched queries under the paper's three range distributions.

Run with multiple fake devices to exercise the collective merge:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_rmq.py
"""

import sys

from repro.launch import serve


def main():
    sys.argv = [sys.argv[0], "--n", str(1 << 20), "--batch", "8192",
                "--batches", "8", "--dist", "small"]
    serve.main()
    sys.argv = [sys.argv[0], "--n", str(1 << 20), "--batch", "8192",
                "--batches", "8", "--dist", "large"]
    serve.main()


if __name__ == "__main__":
    main()
