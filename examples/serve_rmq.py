"""Async RMQ serving demo: concurrent Poisson clients through micro-batches.

Library-level tour of the serve subsystem (`repro.serve`): build an engine
from the capability-aware registry, stand up an `RMQServer`, and drive it
with four open-loop Poisson clients submitting variable-size requests under
mixed range distributions. The deadline micro-batcher coalesces concurrent
requests into power-of-two padded engine launches; every per-request result
is verified bit-identical against the numpy oracle.

Runs on whatever devices the environment provides — use fake devices to
exercise the sharded engine's collective merges:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_rmq.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ref, registry
from repro.serve import RMQServer, ServeConfig
from repro.serve.workload import make_queries, run_poisson_clients

N = 1 << 16
CLIENTS = 4
REQUESTS = 24  # per client
REQ_BATCH = 16  # queries per request
RATE_HZ = 300.0  # per-client offered load (Poisson)
DEADLINE_S = 2e-3
DISTS = ("small", "medium", "large")  # round-robined across clients


def serve_async(engine: str, x: np.ndarray, **build_kwargs) -> None:
    spec = registry.get(engine)
    state = registry.build_for_serving(engine, jnp.asarray(x), **build_kwargs)
    qfn = lambda l, r: spec.query(state, l, r)

    srv = RMQServer(
        qfn, ServeConfig(deadline_s=DEADLINE_S, max_batch=1024, n=N)
    )
    srv.warmup()  # compile every padded launch shape before traffic

    # Each client offers a different §6.4 range regime, so concurrent
    # microbatches mix short and long ranges.
    make_request = lambda rng, c: make_queries(rng, N, REQ_BATCH, DISTS[c % len(DISTS)])
    with srv:
        results = run_poisson_clients(
            CLIENTS, REQUESTS, RATE_HZ, make_request, srv.submit, seed=7_000
        )
        served = bad = 0
        for out in results:
            for (l, r), fut in out:
                if fut is None:
                    continue  # open-loop client dropped on backpressure
                res = fut.result(timeout=300)
                gold = ref.rmq_ref(x, l, r)
                ok = np.array_equal(res.idx, gold) and np.array_equal(res.val, x[gold])
                served += 1
                bad += not ok

    st = srv.stats()
    print(f"[{engine}] {CLIENTS} Poisson clients on {len(jax.devices())} device(s):")
    print(f"  {st.summary()}")
    print(f"  verify: {served - bad}/{served} requests bit-identical to the oracle")
    if bad:
        raise SystemExit(1)


def main() -> None:
    rng = np.random.default_rng(0)
    x = rng.random(N, dtype=np.float32)
    # Single-host range-adaptive crossover engine...
    serve_async("hybrid", x)
    # ...then the mesh-sharded one (degenerates gracefully on 1 device); the
    # batch-sharded mode scales serving throughput with device count.
    serve_async("sharded_hybrid", x, mode="shard_batch")


if __name__ == "__main__":
    main()
