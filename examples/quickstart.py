"""Quickstart: build the RTXRMQ-TPU structure and answer a batch of RMQs.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import block_rmq, lane_rmq, ref
from repro.kernels import ops


def main():
    rng = np.random.default_rng(0)
    n = 1 << 16
    x = rng.random(n, dtype=np.float32)
    l = rng.integers(0, n, 1024)
    r = rng.integers(0, n, 1024)
    l, r = np.minimum(l, r), np.maximum(l, r)

    # paper-faithful blocked engine (pure jnp)
    s = block_rmq.build(jnp.asarray(x), block_size=1024)
    idx, val = block_rmq.query(s, jnp.asarray(l), jnp.asarray(r))

    # same algorithm through the Pallas kernels (interpret mode on CPU)
    sk = ops.build(jnp.asarray(x), 1024)
    idx_k, _ = ops.query(sk, jnp.asarray(l[:64]), jnp.asarray(r[:64]))

    # beyond-paper O(1)-gather engine
    sl = lane_rmq.build(jnp.asarray(x))
    idx_l, _ = lane_rmq.query(sl, jnp.asarray(l), jnp.asarray(r))

    gold = ref.rmq_ref(x, l, r)
    assert (np.asarray(idx) == gold).all()
    assert (np.asarray(idx_k) == gold[:64]).all()
    assert (np.asarray(idx_l) == gold).all()
    print(f"answered {len(l)} RMQs over n={n}; all three engines match the oracle")
    print(f"example: RMQ({l[0]}, {r[0]}) = {int(idx[0])} (value {float(val[0]):.4f})")


if __name__ == "__main__":
    main()
